"""Per-step overhead of the combinator API vs the legacy monoliths (PR 2).

The combinator redesign (repro.core.combinators) replaced the monolithic
gum/galore/fira update functions with chains of small transforms.  Under jit
the chains fuse into the same XLA program, so the steady-state step time
should be unchanged — this benchmark proves (or disproves) that, per
optimizer, on a synthetic stacked-family tree at the smoke operating point.

Emits ``name,us_per_call,derived`` CSV rows (derived = overhead_pct of the
chained vs legacy step) and a ``BENCH_optimizer_api.json`` trajectory entry
under --out (default results/) so regressions are visible across PRs.

Usage: PYTHONPATH=src python benchmarks/optimizer_api.py [--steps N] [--out DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

import repro.core as core
from repro.core import apply_updates, legacy

KEY = jax.random.PRNGKey(0)

# Stacked-family tree roughly at the LLaMA-60M smoke operating point.
PARAMS = {
    "blocks": {
        "wq": jax.random.normal(KEY, (8, 256, 512)) * 0.02,
        "w_out": jax.random.normal(jax.random.fold_in(KEY, 1), (8, 512, 256)) * 0.02,
    },
    "embed": jax.random.normal(jax.random.fold_in(KEY, 2), (4096, 256)) * 0.02,
    "norm_scale": jnp.ones((256,)),
}

OPT_KW = dict(rank=32, period=50, seed=0, kernel_impl="jnp")


def _builders():
    return [
        ("gum", lambda: core.gum(1e-3, gamma=2, **OPT_KW),
                lambda: legacy.gum(1e-3, gamma=2, **OPT_KW)),
        ("galore", lambda: core.galore(1e-3, **OPT_KW),
                   lambda: legacy.galore(1e-3, **OPT_KW)),
        ("galore_muon", lambda: core.galore(1e-3, base="muon", **OPT_KW),
                        lambda: legacy.galore(1e-3, base="muon", **OPT_KW)),
        ("fira", lambda: core.fira(1e-3, **OPT_KW),
                 lambda: legacy.fira(1e-3, **OPT_KW)),
    ]


def _time_step(opt, steps: int) -> float:
    st = opt.init(PARAMS)
    g = jax.tree_util.tree_map(lambda p: 0.01 * jnp.ones_like(p), PARAMS)

    @jax.jit
    def step(p, s):
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s

    p = PARAMS
    p, st = step(p, st)  # compile
    jax.block_until_ready(jax.tree_util.tree_leaves(p)[0])
    t0 = time.perf_counter()
    for _ in range(steps):
        p, st = step(p, st)
    jax.block_until_ready(jax.tree_util.tree_leaves(p)[0])
    return (time.perf_counter() - t0) / steps * 1e6


def main() -> None:
    from _smoke import smoke, steps as smoke_steps

    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--out", default="results")
    args, _ = ap.parse_known_args()
    n_steps = smoke_steps(args.steps, 1)

    print("name,us_per_call,derived")
    rows = []
    for name, new_b, old_b in _builders():
        us_new = _time_step(new_b(), n_steps)
        us_old = _time_step(old_b(), n_steps)
        overhead = (us_new - us_old) / us_old * 100.0
        print(f"optapi_{name}_chained,{us_new:.0f},overhead_pct={overhead:+.1f}")
        print(f"optapi_{name}_legacy,{us_old:.0f},baseline")
        rows.append({"optimizer": name, "us_chained": round(us_new, 1),
                     "us_legacy": round(us_old, 1),
                     "overhead_pct": round(overhead, 2)})

    if smoke():
        print("# smoke mode: skipping BENCH_optimizer_api.json write", flush=True)
        return
    os.makedirs(args.out, exist_ok=True)
    entry = {
        "suite": "optimizer_api",
        "backend": jax.default_backend(),
        "steps": n_steps,
        "kernel_impl": OPT_KW["kernel_impl"],
        "rows": rows,
    }
    path = os.path.join(args.out, "BENCH_optimizer_api.json")
    with open(path, "w") as f:
        json.dump(entry, f, indent=2)
    print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    main()
