"""Per-step overhead of the combinator API's execution modes (PR 2/PR 3).

The combinator redesign (repro.core.combinators) expressed each optimizer
as a chain of small transforms; PR 3 added the family-stacked execution
engine on top.  With the frozen monoliths deleted (PR 7), the per-leaf
chained path IS the reference semantics — this benchmark times it as the
baseline and reports the family-stacked engine's delta against it, per
optimizer, on a synthetic stacked-family tree at the smoke operating point.
(The historical chained-vs-monolith numbers live in the committed
``BENCH_optimizer_api.json`` history; the trajectory guarantee itself is
tests/test_legacy_fixtures.py.)

Emits ``name,us_per_call,derived`` CSV rows (derived = overhead_pct of the
stacked vs chained step) and a ``BENCH_optimizer_api.json`` trajectory entry
under --out (default results/) so regressions are visible across PRs.

Usage: PYTHONPATH=src python benchmarks/optimizer_api.py [--steps N] [--out DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

import repro.core as core
from repro.core import apply_updates

KEY = jax.random.PRNGKey(0)

# Stacked-family tree roughly at the LLaMA-60M smoke operating point.
PARAMS = {
    "blocks": {
        "wq": jax.random.normal(KEY, (8, 256, 512)) * 0.02,
        "w_out": jax.random.normal(jax.random.fold_in(KEY, 1), (8, 512, 256)) * 0.02,
    },
    "embed": jax.random.normal(jax.random.fold_in(KEY, 2), (4096, 256)) * 0.02,
    "norm_scale": jnp.ones((256,)),
}

OPT_KW = dict(rank=32, period=50, seed=0, kernel_impl="jnp")


def _builders():
    return [
        ("gum", lambda **kw: core.gum(1e-3, gamma=2, **OPT_KW, **kw)),
        ("galore", lambda **kw: core.galore(1e-3, **OPT_KW, **kw)),
        ("galore_muon", lambda **kw: core.galore(1e-3, base="muon",
                                                 **OPT_KW, **kw)),
        ("fira", lambda **kw: core.fira(1e-3, **OPT_KW, **kw)),
    ]


def _time_step(opt, steps: int) -> float:
    st = opt.init(PARAMS)
    g = jax.tree_util.tree_map(lambda p: 0.01 * jnp.ones_like(p), PARAMS)

    @jax.jit
    def step(p, s):
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s

    p = PARAMS
    p, st = step(p, st)  # compile
    jax.block_until_ready(jax.tree_util.tree_leaves(p)[0])
    t0 = time.perf_counter()
    for _ in range(steps):
        p, st = step(p, st)
    jax.block_until_ready(jax.tree_util.tree_leaves(p)[0])
    return (time.perf_counter() - t0) / steps * 1e6


def main() -> None:
    from _smoke import smoke, steps as smoke_steps

    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--out", default="results")
    args, _ = ap.parse_known_args()
    n_steps = smoke_steps(args.steps, 1)

    print("name,us_per_call,derived")
    rows = []
    for name, build in _builders():
        us_chained = _time_step(build(), n_steps)
        us_stacked = _time_step(build(fuse_families=True), n_steps)
        overhead = (us_stacked - us_chained) / us_chained * 100.0
        print(f"optapi_{name}_chained,{us_chained:.0f},baseline")
        print(f"optapi_{name}_stacked,{us_stacked:.0f},"
              f"overhead_pct={overhead:+.1f}")
        rows.append({"optimizer": name, "us_chained": round(us_chained, 1),
                     "us_stacked": round(us_stacked, 1),
                     "overhead_pct": round(overhead, 2)})

    if smoke():
        print("# smoke mode: skipping BENCH_optimizer_api.json write", flush=True)
        return
    os.makedirs(args.out, exist_ok=True)
    entry = {
        "suite": "optimizer_api",
        "backend": jax.default_backend(),
        "steps": n_steps,
        "kernel_impl": OPT_KW["kernel_impl"],
        "baseline": "chained (per-leaf combinator path)",
        "rows": rows,
    }
    path = os.path.join(args.out, "BENCH_optimizer_api.json")
    with open(path, "w") as f:
        json.dump(entry, f, indent=2)
    print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    main()
