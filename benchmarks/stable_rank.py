"""Paper Figures 2/3/5: stable rank and singular-value spectra of trained
weights — GUM's high-rank updates should produce HIGHER stable rank
E[||M||_F^2 / ||M||_2^2] and flatter spectra than GaLore's.

We train LLaMA-60M (smoke) for a few hundred steps with GaLore-Muon vs GUM
at matched memory and compare the mean stable rank across block matrices.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core import OptimizerConfig, apply_updates, build_optimizer, clip_by_global_norm
from repro.data import DataConfig, build_stream
from repro.models import build_model


def stable_rank(w: jax.Array) -> float:
    s = jnp.linalg.svd(w.astype(jnp.float32), compute_uv=False)
    return float(jnp.sum(s**2) / (s[0] ** 2 + 1e-12))


def spectrum_flatness(w: jax.Array) -> float:
    """Tail mass: fraction of spectral energy outside the top-1 direction."""
    s = jnp.linalg.svd(w.astype(jnp.float32), compute_uv=False)
    return float(1.0 - s[0] ** 2 / (jnp.sum(s**2) + 1e-12))


def train(method: str, steps: int = 120):
    cfg = get_smoke("llama-60m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ocfg = {
        "galore_muon": OptimizerConfig(name="galore_muon", lr=1e-2, rank=8, period=20),
        "gum": OptimizerConfig(name="gum", lr=1e-2, rank=4, gamma=1, period=20),
    }[method]
    opt = build_optimizer(ocfg)
    st = opt.init(params)
    stream = build_stream(DataConfig(vocab=cfg.vocab, seq_len=128,
                                     global_batch=8, seed=0))

    @jax.jit
    def step(p, s, tokens):
        def loss_fn(p):
            lg, aux, _ = model.forward(p, tokens)
            return model.loss(lg, tokens, aux)
        loss, g = jax.value_and_grad(loss_fn)(p)
        g = clip_by_global_norm(g, 1.0)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s, loss

    for i in range(steps):
        params, st, loss = step(params, st, jnp.asarray(stream.batch_at(i)))
    return params, float(loss)


def mean_block_stable_rank(params) -> tuple[float, float]:
    ranks, flats = [], []
    for name in ("wq", "wk", "wv", "wo"):
        w = params["blocks"]["attn"][name]
        for l in range(w.shape[0]):
            ranks.append(stable_rank(w[l]))
            flats.append(spectrum_flatness(w[l]))
    for name in ("w_in", "w_out", "w_gate"):
        if name in params["blocks"]["mlp"]:
            w = params["blocks"]["mlp"][name]
            for l in range(w.shape[0]):
                ranks.append(stable_rank(w[l]))
                flats.append(spectrum_flatness(w[l]))
    return sum(ranks) / len(ranks), sum(flats) / len(flats)


def main() -> None:
    from _smoke import steps as smoke_steps

    print("name,us_per_call,derived")
    out = {}
    for method in ("galore_muon", "gum"):
        params, loss = train(method, steps=smoke_steps(120))
        sr, flat = mean_block_stable_rank(params)
        out[method] = (sr, flat, loss)
        print(f"stable_rank_fig2_{method},0,stable_rank={sr:.3f};"
              f"tail_energy={flat:.4f};final_loss={loss:.4f}")
    print(
        f"stable_rank_fig2_summary,0,"
        f"gum_rank_gain={out['gum'][0] - out['galore_muon'][0]:+.3f};"
        f"gum_tail_gain={out['gum'][1] - out['galore_muon'][1]:+.4f}"
    )


if __name__ == "__main__":
    main()
