"""Shared --smoke plumbing for the benchmark suites.

``benchmarks/run.py --smoke`` exports ``BENCH_SMOKE=1``; each suite clamps
its step counts through :func:`steps` and skips result-JSON writes through
:func:`smoke` (a 1–2-step smoke run makes no timing claims, and must not
clobber real ``results/BENCH_*.json`` trajectories).  A tier-1 test invokes
the smoke mode end-to-end so benchmark suites cannot silently bit-rot.
"""
from __future__ import annotations

import os


def smoke() -> bool:
    return os.environ.get("BENCH_SMOKE") == "1"


def steps(default: int, smoke_steps: int = 2) -> int:
    """Clamp a suite's step count in smoke mode."""
    return min(default, smoke_steps) if smoke() else default
