"""Paper Table 4 proxy: pre-training comparison across optimizers.

The paper pre-trains LLaMA-{60M,130M,350M} on C4 and evaluates commonsense
benchmarks.  Offline, we run the same *optimizer comparison* on the paper's
LLaMA-60M architecture over the synthetic C4-like stream and report final
training loss (the pre-training-quality proxy): AdamW, Muon, GaLore, Fira,
GUM — the exact Table-4 method set.  Hyperparameters follow Appendix C.3
scaled to the short run (rank 256->16 scale-equivalent on the small width,
gamma from Table 7, K scaled with total steps).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core import OptimizerConfig, apply_updates, build_optimizer, clip_by_global_norm
from repro.data import DataConfig, build_stream
from repro.models import build_model

METHODS = {
    "adamw": OptimizerConfig(name="adamw", lr=3e-3),
    "muon": OptimizerConfig(name="muon", lr=1e-2, beta=0.95),
    "galore": OptimizerConfig(name="galore", lr=1e-2, rank=16, period=20),
    "fira": OptimizerConfig(name="fira", lr=1e-2, rank=16, period=20),
    "gum": OptimizerConfig(name="gum", lr=1e-2, rank=8, gamma=1, period=20,
                           base="muon"),
}


def run_method(name: str, steps: int = 60, batch: int = 8, seq: int = 128):
    cfg = get_smoke("llama-60m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = build_optimizer(METHODS[name])
    st = opt.init(params)
    stream = build_stream(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                     global_batch=batch, seed=0))

    @jax.jit
    def step(p, s, tokens):
        def loss_fn(p):
            lg, aux, _ = model.forward(p, tokens)
            return model.loss(lg, tokens, aux)

        loss, g = jax.value_and_grad(loss_fn)(p)
        g = clip_by_global_norm(g, 1.0)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s, loss

    losses = []
    t0 = time.time()
    for i in range(steps):
        tokens = jnp.asarray(stream.batch_at(i))
        params, st, loss = step(params, st, tokens)
        losses.append(float(loss))
    dt = (time.time() - t0) / steps * 1e6
    return losses, dt


def main() -> None:
    from _smoke import steps as smoke_steps

    print("name,us_per_call,derived")
    finals = {}
    for m in METHODS:
        losses, us = run_method(m, steps=smoke_steps(60))
        last5 = sum(losses[-5:]) / len(losses[-5:])
        finals[m] = last5
        print(f"pretrain_table4_{m},{us:.0f},first={losses[0]:.3f};final5={last5:.4f}")
    # paper's qualitative ordering claims: GUM <= GaLore (and close to Muon)
    print(
        f"pretrain_table4_summary,0,"
        f"gum_minus_galore={finals['gum'] - finals['galore']:+.4f};"
        f"gum_minus_muon={finals['gum'] - finals['muon']:+.4f}"
    )


if __name__ == "__main__":
    main()
