"""Batched decode serving with the KV cache.

Serves a batch of prompts: prefill populates the cache, then a jit'd
serve_step generates tokens autoregressively (greedy).  The same serve_step
is what the decode_* dry-run cells lower onto the production meshes.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.launch.steps import make_serve_step
from repro.models import build_model

cfg = get_smoke("qwen1.5-4b")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

B, PROMPT, GEN, MAX = 4, 12, 20, 64
prompts = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 0, cfg.vocab)

# --- prefill: run the prompt token-by-token through the decode path
# (a production server would use the fused prefill step; token-by-token
# keeps this example minimal and exercises the exact serving kernel).
cache = model.init_cache(batch=B, max_seq=MAX, dtype=jnp.float32)
serve_step = jax.jit(make_serve_step(model), donate_argnums=(1,))

t0 = time.time()
logits = None
for i in range(PROMPT):
    logits, cache = serve_step(params, cache, prompts[:, i : i + 1], jnp.int32(i))
prefill_s = time.time() - t0

# --- decode: greedy generation
out_tokens = []
tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
t0 = time.time()
for i in range(PROMPT, PROMPT + GEN):
    out_tokens.append(tok)
    logits, cache = serve_step(params, cache, tok, jnp.int32(i))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
jax.block_until_ready(tok)
decode_s = time.time() - t0

gen = jnp.concatenate(out_tokens, axis=1)
print(f"prefill: {prefill_s*1e3:.1f} ms   decode: {decode_s/GEN*1e3:.2f} ms/token")
print("generated token grid (greedy):")
for b in range(B):
    print(" ", [int(t) for t in gen[b]])
print("serve OK")
