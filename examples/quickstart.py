"""Quickstart: train a tiny LLaMA with GUM in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core import OptimizerConfig, apply_updates, build_optimizer
from repro.data import DataConfig, build_stream
from repro.models import build_model

cfg = get_smoke("llama-60m")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

# GUM: rank-8 GaLore projection + 1 full-rank sampled layer per period of 10
opt = build_optimizer(OptimizerConfig(name="gum", lr=5e-3, rank=8, gamma=1, period=10))
opt_state = opt.init(params)

stream = build_stream(DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=8))


@jax.jit
def train_step(params, opt_state, tokens):
    def loss_fn(p):
        logits, aux, _ = model.forward(p, tokens)
        return model.loss(logits, tokens, aux)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    updates, opt_state = opt.update(grads, opt_state, params)
    return apply_updates(params, updates), opt_state, loss


for step, tokens in zip(range(30), stream):
    params, opt_state, loss = train_step(params, opt_state, jnp.asarray(tokens))
    if step % 10 == 0 or step == 29:
        print(f"step {step:3d}  loss {float(loss):.4f}")
print("quickstart OK")
