"""Quickstart: train a tiny LLaMA with GUM in ~30 lines — then compose a
brand-new unbiased optimizer from the combinator API in one expression.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core import (
    OptimizerConfig,
    add_decayed_weights,
    apply_updates,
    build_optimizer,
    chain,
    layerwise_unbias,
    lowrank,
    scale_by_adam,
    scale_by_lr,
    with_matrix_routing,
)
from repro.core.adamw import adamw
from repro.data import DataConfig, build_stream
from repro.models import build_model

cfg = get_smoke("llama-60m")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

# GUM: rank-8 GaLore projection + 1 full-rank sampled layer per period of 10.
# Under the hood this IS a combinator chain:
#   chain(lowrank(layerwise_unbias(scale_by_muon())), add_decayed_weights(),
#         scale_by_lr()) routed against an AdamW fallback.
#
# Family-stacked fused execution (PR 3): add fuse_families=True to run the
# whole low-rank pipeline as ONE batched launch per shape family instead of
# one per parameter leaf — trajectory-identical (bit-exact on the jnp path
# at deterministic shapes; large threaded-GEMM shapes can round <=1 fp32 ulp
# apart), just faster:
#   OptimizerConfig(name="gum", ..., fuse_families=True)
# fused_epilogue=True additionally folds the -lr/weight-decay chain tail
# into the back-projection GEMM kernel for optimizers whose update lowrank()
# back-projects (galore / galore_muon / golore); gum and fira emit
# full-shape updates themselves, so for them the knob is inert.  Same knobs
# on lowrank() for hand-composed chains, and as --fuse-families /
# --fused-epilogue on repro.launch.train / dryrun.
opt = build_optimizer(OptimizerConfig(name="gum", lr=5e-3, rank=8, gamma=1, period=10))
opt_state = opt.init(params)

stream = build_stream(DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=8))


@jax.jit
def train_step(params, opt_state, tokens):
    def loss_fn(p):
        logits, aux, _ = model.forward(p, tokens)
        return model.loss(logits, tokens, aux)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    updates, opt_state = opt.update(grads, opt_state, params)
    return apply_updates(params, updates), opt_state, loss


for step, tokens in zip(range(30), stream):
    params, opt_state, loss = train_step(params, opt_state, jnp.asarray(tokens))
    if step % 10 == 0 or step == 29:
        print(f"step {step:3d}  loss {float(loss):.4f}")
print("quickstart OK")

# ---------------------------------------------------------------------------
# The paradigm is the API: debiasing ANY projected base is one composition.
# Unbiased GaLore-Adam (layerwise_unbias wrapping scale_by_adam) — a new
# optimizer, zero new optimizer files (also available as
# OptimizerConfig(name="unbiased_galore_adam")).
# ---------------------------------------------------------------------------
uga = with_matrix_routing(
    chain(
        lowrank(layerwise_unbias(scale_by_adam(scale=0.25), gamma=1),
                rank=8, period=10, reset_on_refresh=True),
        add_decayed_weights(0.01),
        scale_by_lr(5e-3),
    ),
    adamw(5e-3, weight_decay=0.01),
)
uga_state = uga.init(params)


@jax.jit
def uga_step(params, opt_state, tokens):
    def loss_fn(p):
        logits, aux, _ = model.forward(p, tokens)
        return model.loss(logits, tokens, aux)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    updates, opt_state = uga.update(grads, opt_state, params)
    return apply_updates(params, updates), opt_state, loss


for step, tokens in zip(range(10), stream):
    params, uga_state, loss = uga_step(params, uga_state, jnp.asarray(tokens))
print(f"unbiased GaLore-Adam composition OK  loss {float(loss):.4f}")

# ---------------------------------------------------------------------------
# Adaptive rank (the rank-policy engine, repro.core.rank_policy): gradient
# rank decays during training, so a fixed r wastes optimizer memory early or
# starves the subspace late.  A RankPolicy makes rank a per-family,
# time-varying quantity: `spectral` estimates the captured gradient energy
# from the probes lowrank() stores at each projector refresh and walks rank
# down (or up) a declared ladder.  Rank is a *shape* in JAX, so changes
# happen host-side at refresh boundaries: the controller migrates the
# optimizer state (truncate / zero-pad the rank axes, everything else
# carried bit-for-bit) and you re-fetch the transform + re-jit — bounded by
# the ladder, so at most len(ladder) compilations per run.  The Trainer does
# all of this automatically from OptimizerConfig(rank_policy="spectral:0.9",
# rank_ladder=(4, 8, 16)) (CLI: --rank-policy / --rank-ladder), and persists
# the controller state in checkpoint extras so resume is exact even across a
# rank change.  Hand-driven it is a ~10-line loop:
# ---------------------------------------------------------------------------
from repro.core import rank_policy as rp

policy = rp.spectral(target_energy=0.9, r_min=4, r_max=16, ladder=(4, 8, 16))
build = lambda m: with_matrix_routing(
    chain(
        lowrank(layerwise_unbias(scale_by_adam(scale=0.25), gamma=1),
                rank=m, period=10, reset_on_refresh=True, rank_policy=policy),
        add_decayed_weights(0.01),
        scale_by_lr(5e-3),
    ),
    adamw(5e-3, weight_decay=0.01),
)
ctrl = rp.RankPolicyController(policy, build, period=10, default_rank=16)
ada = ctrl.transform()
ada_state = ada.init(params)


def make_ada_step(ada):
    @jax.jit
    def ada_step(params, opt_state, tokens):
        def loss_fn(p):
            logits, aux, _ = model.forward(p, tokens)
            return model.loss(logits, tokens, aux)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, opt_state = ada.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    return ada_step


ada_steps = {ctrl.current_map: make_ada_step(ada)}
for step, tokens in zip(range(25), stream):
    ada_state, changed = ctrl.maybe_update(ada_state, params)
    if changed:  # rank migrated at a refresh boundary: re-fetch + re-jit
        ada = ctrl.transform()
        ada_steps.setdefault(ctrl.current_map, make_ada_step(ada))
        print(f"step {step:3d}  rank -> {ctrl.current_map}")
    params, ada_state, loss = ada_steps[ctrl.current_map](
        params, ada_state, jnp.asarray(tokens))
print(f"adaptive-rank composition OK  loss {float(loss):.4f}")
print(f"rank history: {ctrl.history}")
