"""End-to-end pre-training driver: the paper's LLaMA-130M with GUM.

This is the Table-4 production driver — full config system, checkpointing,
auto-resume, NaN guard, straggler monitor.  At full scale (default flags on
real hardware) it trains the real 130M model for a few hundred steps on the
C4-like stream; pass ``--tiny`` on CPU for a fast functional run.

    PYTHONPATH=src python examples/pretrain_llama130m.py --tiny
    PYTHONPATH=src python examples/pretrain_llama130m.py \
        --steps 300 --batch 128 --seq 1024        # production
"""
import argparse

import jax

from repro.configs import RunConfig, get_config, get_smoke
from repro.core import OptimizerConfig
from repro.data import DataConfig
from repro.models import build_model
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--seq", type=int, default=1024)
    ap.add_argument("--ckpt", default="/tmp/repro_pretrain_130m")
    args = ap.parse_args()

    if args.tiny:
        cfg = get_smoke("llama-130m")
        args.steps, args.batch, args.seq = min(args.steps, 40), 4, 128
        opt = OptimizerConfig(name="gum", lr=5e-3, rank=8, gamma=1, period=10)
    else:
        cfg = get_config("llama-130m")
        # Appendix C.3: rank 256, gamma 4, K=100 for the 130M model
        opt = OptimizerConfig(name="gum", lr=5e-3, rank=256, gamma=4, period=100)

    model = build_model(cfg)
    trainer = Trainer(
        model,
        opt,
        RunConfig(steps=args.steps, ckpt_dir=args.ckpt,
                  ckpt_every=max(args.steps // 4, 1), log_every=10),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
                   num_hosts=jax.process_count(), host_id=jax.process_index()),
    )
    res = trainer.train()
    print(
        f"pretrain done: {res.final_step} steps, "
        f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}, "
        f"nan-skips={res.skipped_nonfinite}, stragglers={len(res.straggler_steps)}"
    )


if __name__ == "__main__":
    main()
