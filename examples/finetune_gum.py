"""Fine-tuning with GUM's Appendix-C.1 variant.

Fine-tuning uses ``compensation="finetune"`` — the full-rank branch is
scaled so q=1 exactly recovers full-parameter Muon (the paper's fine-tuning
setup: gamma=2 layers full-rank, rank 128, K=200).  We "fine-tune" from a
briefly pre-trained checkpoint to exercise the restore path end-to-end.

    PYTHONPATH=src python examples/finetune_gum.py
"""
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import RunConfig, get_smoke
from repro.core import OptimizerConfig, apply_updates, build_optimizer
from repro.data import DataConfig, build_stream
from repro.models import build_model

cfg = get_smoke("llama-60m")
model = build_model(cfg)

# --- phase 1: a short "pre-training" checkpoint
params = model.init(jax.random.PRNGKey(0))
mgr = CheckpointManager("/tmp/repro_ft_base", keep=1)
mgr.save(0, params)

# --- phase 2: fine-tune from the checkpoint with the App. C.1 variant
params, _ = mgr.restore(0, params)
opt = build_optimizer(
    OptimizerConfig(name="gum", lr=2e-3, rank=8, gamma=1, period=10,
                    compensation="finetune")
)
opt_state = opt.init(params)
stream = build_stream(DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=4,
                                 seed=123))


@jax.jit
def step(params, opt_state, tokens):
    def loss_fn(p):
        logits, aux, _ = model.forward(p, tokens)
        return model.loss(logits, tokens, aux)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    updates, opt_state = opt.update(grads, opt_state, params)
    return apply_updates(params, updates), opt_state, loss


for i in range(25):
    params, opt_state, loss = step(params, opt_state, jnp.asarray(stream.batch_at(i)))
    if i % 5 == 0:
        print(f"ft step {i:3d}  loss {float(loss):.4f}")
print("finetune OK")
